"""HLO-text analysis for the dry-run: collective-bytes accounting.

``compiled.cost_analysis()`` reports FLOPs and bytes-accessed but NOT
collective traffic; we parse the optimized HLO and sum the result-shape
bytes of every collective op (all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute).

Two subtleties handled:
  * **while loops** (scan-over-layers): collectives in a loop body appear
    once in the text but run ``trip_count`` times. We parse computations,
    attribute collectives to their computation, and multiply through the
    while-call graph using XLA's ``known_trip_count`` backend config
    (default 1 when unknown).
  * **result-shape proxy**: result bytes are the standard first-order proxy
    for per-participant traffic (ring transfer differs by <= 2(n-1)/n);
    the roofline tables note this.
"""

from __future__ import annotations

import re
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1,
    "pred": 1, "c64": 8, "c128": 16,
}

COLLECTIVE_OPS = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_COMP_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\([^)]*\))?.*\{\s*$")
_WHILE_RE = re.compile(r"\bwhile\(")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count["\s:{]+n["\s:]+"?(\d+)')
_CALL_RE = re.compile(r"\b(?:call|fusion)\(")
_TO_APPLY_RE = re.compile(r"(?:to_apply|calls)=%?([\w.\-]+)")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _line_collective(line: str):
    """(op_kind, result_bytes) if this line is a collective, else None."""
    for k in COLLECTIVE_OPS:
        if f" {k}(" in line or f" {k}-start(" in line:
            eq = line.find("=")
            op_pos = line.find(k, eq)
            head = line[eq + 1 : op_pos] if eq >= 0 and op_pos > eq else line
            nbytes = sum(
                _shape_bytes(d, s) for d, s in _SHAPE_RE.findall(head)
            )
            # -done ops repeat the -start shape: count starts only
            if f" {k}-done(" in line:
                return None
            return k, nbytes
    return None


def _split_computations(hlo_text: str) -> Dict[str, List[str]]:
    """computation name -> its lines (brace-depth tracked)."""
    comps: Dict[str, List[str]] = {}
    cur_name = None
    cur_lines: List[str] = []
    depth = 0
    entry_name = None
    for line in hlo_text.splitlines():
        if depth == 0:
            m = _COMP_HEADER_RE.match(line.strip()) if "{" in line else None
            if m and ("(" in line or line.strip().startswith("ENTRY")):
                cur_name = m.group(1)
                if line.strip().startswith("ENTRY"):
                    entry_name = cur_name
                cur_lines = []
                depth = line.count("{") - line.count("}")
                if depth <= 0:
                    cur_name = None
                continue
        else:
            depth += line.count("{") - line.count("}")
            if depth <= 0:
                comps[cur_name] = cur_lines
                cur_name = None
                cur_lines = []
                continue
            cur_lines.append(line)
    if entry_name is not None:
        comps["__entry__"] = comps.get(entry_name, [])
    return comps


def collective_bytes(hlo_text: str) -> Dict[str, dict]:
    """Trip-count-weighted collective traffic of the entry computation."""
    comps = _split_computations(hlo_text)
    if not comps:
        comps = {"__entry__": hlo_text.splitlines()}

    direct: Dict[str, Dict[str, float]] = {}
    calls: Dict[str, List[Tuple[str, int]]] = {}
    counts: Dict[str, Dict[str, int]] = {}
    for name, lines in comps.items():
        d = {k: 0.0 for k in COLLECTIVE_OPS}
        c = {k: 0 for k in COLLECTIVE_OPS}
        cl: List[Tuple[str, int]] = []
        for line in lines:
            hit = _line_collective(line)
            if hit:
                d[hit[0]] += hit[1]
                c[hit[0]] += 1
            if _WHILE_RE.search(line):
                bm = _BODY_RE.search(line)
                if bm:
                    tm = _TRIP_RE.search(line)
                    trip = int(tm.group(1)) if tm else 1
                    cl.append((bm.group(1), trip))
            elif _CALL_RE.search(line):
                tm = _TO_APPLY_RE.search(line)
                if tm:
                    cl.append((tm.group(1), 1))
        direct[name] = d
        counts[name] = c
        calls[name] = cl

    memo: Dict[str, Dict[str, float]] = {}

    def resolve(name: str, stack=()) -> Dict[str, float]:
        if name in memo:
            return memo[name]
        if name in stack or name not in direct:
            return {k: 0.0 for k in COLLECTIVE_OPS}
        total = dict(direct[name])
        for callee, trip in calls[name]:
            sub = resolve(callee, stack + (name,))
            for k in COLLECTIVE_OPS:
                total[k] += trip * sub[k]
        memo[name] = total
        return total

    entry = resolve("__entry__")
    entry_counts = {k: sum(c[k] for c in counts.values())
                    for k in COLLECTIVE_OPS}
    entry["total"] = sum(entry[k] for k in COLLECTIVE_OPS)
    return {"bytes": entry, "counts": entry_counts}


def while_trip_counts(hlo_text: str) -> Dict[str, int]:
    out = {}
    for m in re.finditer(r'known_trip_count[^0-9]*(\d+)', hlo_text):
        out[f"loop{len(out)}"] = int(m.group(1))
    return out


# ---------------------------------------------------------------------------
# Trip-count-aware FLOP / byte accounting
#
# XLA's HloCostAnalysis (and hence compiled.cost_analysis()) visits a while
# body ONCE — scan-over-layers models under-report by the trip count. We
# re-derive both metrics from the optimized HLO text:
#   * FLOPs: 2 * prod(result dims) * prod(lhs contracting dims) per `dot`,
#     resolved through the call graph with known_trip_count weights
#     (dots dominate >95% of FLOPs in these models; elementwise ignored).
#   * bytes: sum of (result + operand) bytes per top-level instruction —
#     post-fusion HLO means each fusion's operands/results are exactly its
#     HBM traffic; fusion-body computations contribute zero bytes.
# ---------------------------------------------------------------------------

_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*(.*)$")
_TUPLE_SHAPES_RE = _SHAPE_RE
_OPND_RE = re.compile(r"%[\w.\-]+")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_DOT_OP_RE = re.compile(r"\b(dot|convolution)\(")
_SKIP_BYTES_OPS = (
    "parameter(", "constant(", "tuple(", "get-tuple-element(", "bitcast(",
    "while(", "conditional(", "after-all(", "iota(",
)


def _shapes_and_bytes(segment: str) -> Tuple[list, int]:
    shapes = _SHAPE_RE.findall(segment)
    return shapes, sum(_shape_bytes(d, s) for d, s in shapes)


_PARAM_RE = re.compile(r"^\s*(%[\w.\-]+)\s*=\s*[^=]*parameter\((\d+)\)")


def _slice_param_bytes(lines) -> Dict[int, int]:
    """For a fusion body: params consumed ONLY through dynamic-slice /
    slice ops -> the slice-result bytes actually read. This prevents a
    scan body's weight-slicing fusion from billing the whole stacked
    [L, ...] array every iteration."""
    param_names = {}
    for line in lines:
        m = _PARAM_RE.match(line)
        if m:
            param_names[m.group(1)] = int(m.group(2))
    if not param_names:
        return {}
    uses: Dict[str, list] = {n: [] for n in param_names}
    for line in lines:
        dm = _DEF_RE.match(line)
        if not dm or dm.group(2).strip().startswith("parameter"):
            continue
        opm = re.search(r"\b([\w\-]+)\(", dm.group(2))
        if not opm:
            continue
        op = opm.group(1)
        seg = dm.group(2)[opm.end():]
        cut = seg.find(")")
        for o in _OPND_RE.findall(seg[:cut] if cut >= 0 else seg):
            if o in uses:
                _, res_b = _shapes_and_bytes(dm.group(2)[:opm.start()])
                uses[o].append((op, res_b))
    out: Dict[int, int] = {}
    for name, idx in param_names.items():
        us = uses.get(name, [])
        if us and all(op in ("dynamic-slice", "slice", "bitcast", "reshape",
                             "copy") for op, _ in us):
            out[idx] = sum(b for _, b in us)
    return out


def hlo_metrics(hlo_text: str) -> Dict[str, float]:
    """Trip-count-weighted {flops, bytes} of the entry computation."""
    comps = _split_computations(hlo_text)
    if not comps:
        comps = {"__entry__": hlo_text.splitlines()}

    # identify fusion-body computations (zero HBM bytes) + their
    # slice-only-consumed params
    fusion_bodies = set()
    for lines in comps.values():
        for line in lines:
            if " fusion(" in line:
                m = _TO_APPLY_RE.search(line)
                if m:
                    fusion_bodies.add(m.group(1))
    slice_params = {
        name: _slice_param_bytes(comps[name])
        for name in fusion_bodies
        if name in comps
    }

    direct_flops: Dict[str, float] = {}
    direct_bytes: Dict[str, float] = {}
    calls: Dict[str, List[Tuple[str, int]]] = {}

    for name, lines in comps.items():
        # pass 1: symbol table name -> result bytes / shapes
        sym_shapes: Dict[str, list] = {}
        sym_bytes: Dict[str, int] = {}
        parsed = []
        for line in lines:
            dm = _DEF_RE.match(line)
            if not dm:
                continue
            lhs_name, rest = dm.group(1), dm.group(2)
            # result region: everything before the op token "opname("
            op_m = re.search(r"\b([\w\-]+)\(", rest)
            if not op_m:
                continue
            result_seg = rest[: op_m.start()]
            shapes, nbytes = _shapes_and_bytes(result_seg)
            sym_shapes[lhs_name] = shapes
            sym_bytes[lhs_name] = nbytes
            parsed.append((lhs_name, rest, op_m.group(1), op_m.end()))

        flops = 0.0
        nbytes_total = 0.0
        cl: List[Tuple[str, int]] = []
        for lhs_name, rest, op, op_end in parsed:
            # call graph edges
            if op == "while":
                bm = _BODY_RE.search(rest)
                if bm:
                    tm = _TRIP_RE.search(rest)
                    cl.append((bm.group(1), int(tm.group(1)) if tm else 1))
                continue
            if op in ("fusion", "call", "conditional", "map", "reduce",
                      "reduce-window", "sort", "scatter", "select-and-scatter"):
                for tm in re.finditer(r"(?:calls|to_apply|branch_computations)="
                                      r"\{?%?([\w.\-]+)", rest):
                    cl.append((tm.group(1), 1))

            # operand region: from op( to the metadata/dnums tail
            opnd_seg = rest[op_end:]
            cut = opnd_seg.find(")")
            opnd_names = _OPND_RE.findall(
                opnd_seg[:cut] if cut >= 0 else opnd_seg)

            # FLOPs: dots
            if op == "dot":
                res_elems = 1
                for d, s in sym_shapes.get(lhs_name, []):
                    if s:
                        for x in s.split(","):
                            res_elems *= int(x)
                contract = 1
                cm = _LHS_CONTRACT_RE.search(rest)
                if cm and opnd_names:
                    lhs_shapes = sym_shapes.get(opnd_names[0], [])
                    if lhs_shapes:
                        dims = lhs_shapes[0][1].split(",") if lhs_shapes[0][1] else []
                        for ci in cm.group(1).split(","):
                            if ci and int(ci) < len(dims):
                                contract *= int(dims[int(ci)])
                flops += 2.0 * res_elems * contract

            # bytes: result + operands (skip pure-metadata ops). Slicing
            # patterns stream only the touched region, not the full array:
            #   dynamic-slice            -> 2 x result (read slice + write)
            #   dynamic-update-slice     -> 2 x update operand (in-place RMW)
            #   fusions named *slice*    -> operands capped at result size
            if not any(rest.startswith(s) or f" {s}" in rest[:op_end + 1]
                       for s in _SKIP_BYTES_OPS):
                res_b = sym_bytes.get(lhs_name, 0)
                if op == "dynamic-slice":
                    nbytes_total += 2 * res_b
                elif op == "dynamic-update-slice":
                    upd = (sym_bytes.get(opnd_names[1], res_b)
                           if len(opnd_names) > 1 else res_b)
                    nbytes_total += 2 * upd
                elif op == "fusion":
                    nbytes_total += res_b
                    callee_m = _TO_APPLY_RE.search(rest)
                    sp = slice_params.get(
                        callee_m.group(1) if callee_m else "", {})
                    legacy_slice = "slice" in lhs_name
                    for i, o in enumerate(opnd_names):
                        full = sym_bytes.get(o, 0)
                        if i in sp:
                            nbytes_total += min(full, sp[i])
                        elif legacy_slice:
                            nbytes_total += min(full, res_b)
                        else:
                            nbytes_total += full
                else:
                    nbytes_total += res_b
                    for o in opnd_names:
                        nbytes_total += sym_bytes.get(o, 0)

        direct_flops[name] = flops
        direct_bytes[name] = 0.0 if name in fusion_bodies else nbytes_total
        calls[name] = cl

    memo: Dict[str, Tuple[float, float]] = {}

    def resolve(name: str, stack=()) -> Tuple[float, float]:
        if name in memo:
            return memo[name]
        if name in stack or name not in direct_flops:
            return (0.0, 0.0)
        f = direct_flops[name]
        b = direct_bytes[name]
        for callee, trip in calls[name]:
            cf, cb = resolve(callee, stack + (name,))
            f += trip * cf
            b += trip * cb
        memo[name] = (f, b)
        return (f, b)

    f, b = resolve("__entry__")
    return {"flops": f, "bytes": b}
