"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module never
touches jax device state. The dry-run (and only the dry-run) sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import so the 256-chip single-pod and 512-chip multi-pod meshes can be
built from host placeholder devices.

Production target: TPU v5e pods, 16 x 16 chips per pod;
multi-pod = 2 pods with a leading "pod" data-parallel axis.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(model_axis: int = 1):
    """Small mesh over the locally available devices (tests / examples)."""
    n = len(jax.devices())
    assert n % model_axis == 0
    return jax.make_mesh(
        (n // model_axis, model_axis), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto, jax.sharding.AxisType.Auto))


# TPU v5e hardware constants used by the roofline analysis (per chip).
PEAK_FLOPS_BF16 = 197e12          # 197 TFLOP/s bf16
HBM_BW = 819e9                    # 819 GB/s
ICI_BW_PER_LINK = 50e9            # ~50 GB/s/link
