"""Serving driver: simulate a paper-style serving experiment from the CLI.

  PYTHONPATH=src python -m repro.launch.serve --scheduler edgeserving \
      --lam 200 --slo-ms 50 --platform rtx3080
  PYTHONPATH=src python -m repro.launch.serve --all   # 4 schedulers sweep
"""

from __future__ import annotations

import argparse

from repro.core import (
    ProfileTable,
    SchedulerConfig,
    make_scheduler,
    paper_rate_vector,
    run_experiment,
)

PLATFORMS = {
    "rtx3080": ProfileTable.paper_rtx3080,
    "gtx1650": ProfileTable.paper_gtx1650,
    "jetson": ProfileTable.paper_jetson_orin_nano,
}


def one(name, table, lam, slo, horizon, seed):
    cfg = SchedulerConfig(slo=slo, max_batch=10)
    res = run_experiment(make_scheduler(name, table, cfg), table,
                         paper_rate_vector(lam), horizon=horizon, seed=seed)
    m = res.metrics
    print(f"{name:24s} lam={lam:4.0f}: P95={m.p95_latency*1e3:8.2f}ms "
          f"viol={m.violation_ratio*100:6.2f}% acc={m.mean_accuracy*100:5.2f}% "
          f"depth={m.mean_exit_depth:.2f} dropped={m.dropped}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scheduler", default="edgeserving")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--lam", type=float, default=200.0)
    ap.add_argument("--slo-ms", type=float, default=50.0)
    ap.add_argument("--platform", default="rtx3080", choices=list(PLATFORMS))
    ap.add_argument("--horizon", type=float, default=20.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    table = PLATFORMS[args.platform]()
    scheds = (
        ("edgeserving", "all-final", "all-early", "symphony",
         "earlyexit-lqf", "earlyexit-edf", "allfinal-deadline-aware",
         "ours-bs1")
        if args.all else (args.scheduler,)
    )
    for s in scheds:
        one(s, table, args.lam, args.slo_ms * 1e-3, args.horizon, args.seed)


if __name__ == "__main__":
    main()
