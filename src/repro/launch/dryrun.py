import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes and extract roofline inputs from the compiled
artifacts. No model weights are ever materialised (ShapeDtypeStruct only).

The two lines above MUST stay the first statements in this file: jax locks
the device count at first init, and only the dry-run wants 512 placeholder
host devices (smoke tests and benches see the real single CPU).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                      # all cells
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --mesh multi --out artifacts
"""

import argparse
import json
import time
import traceback
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import (
    ARCH_IDS,
    SHAPES,
    applicable,
    get_config,
    input_specs,
    skip_reason,
)
from repro.distributed.sharding import (
    batch_shardings,
    cache_shardings,
    param_shardings,
    serve_rules,
    train_rules,
)
from repro.launch.hlo_analysis import collective_bytes, hlo_metrics
from repro.launch.mesh import make_production_mesh
from repro.models import build_model
from repro.runtime.trainer import (
    abstract_opt_state,
    make_train_step,
    opt_state_shardings,
    pick_optimizer_for,
)
from jax.sharding import NamedSharding, PartitionSpec as P


def _tree_bytes_per_device(tree, shardings, mesh) -> float:
    """Static per-device bytes of a sharded ShapeDtypeStruct tree."""
    total = 0.0
    for leaf, sh in zip(jax.tree.leaves(tree), jax.tree.leaves(
            shardings, is_leaf=lambda x: isinstance(x, NamedSharding))):
        n = int(np.prod(leaf.shape)) if leaf.shape else 1
        shards = 1
        for ax in jax.tree.leaves(tuple(sh.spec)):
            if ax is not None:
                shards *= mesh.shape[ax]
        total += n * leaf.dtype.itemsize / shards
    return total


def _active_params(cfg, shapes_tree) -> float:
    """Active (per-token) parameter count: total minus the non-routed share
    of expert stacks."""
    total = sum(int(np.prod(s.shape)) for s in jax.tree.leaves(shapes_tree))
    if cfg.num_experts and cfg.top_k:
        flat = jax.tree_util.tree_flatten_with_path(shapes_tree)[0]
        routed = sum(
            int(np.prod(s.shape))
            for path, s in flat
            if any("we_" in str(getattr(p, "key", "")) for p in path)
        )
        total -= routed * (1.0 - cfg.top_k / cfg.num_experts)
    return float(total)


def _model_flops(cfg, shapes_tree, kind: str, shape_spec) -> float:
    """MODEL_FLOPS = 6 N D (train) / 2 N D (inference), N = active params."""
    n_active = _active_params(cfg, shapes_tree)
    if kind == "train":
        tokens = shape_spec.global_batch * shape_spec.seq_len
        return 6.0 * n_active * tokens
    if kind == "prefill":
        tokens = shape_spec.global_batch * shape_spec.seq_len
        return 2.0 * n_active * tokens
    tokens = shape_spec.global_batch  # decode: one token per sequence
    return 2.0 * n_active * tokens


def _serve_cast(shapes_tree, dtype):
    """Serving stores weights in the compute dtype (bf16) — no fp32 master
    copy exists outside training."""
    def one(s):
        if jnp.issubdtype(s.dtype, jnp.floating):
            return jax.ShapeDtypeStruct(s.shape, dtype)
        return s
    return jax.tree.map(one, shapes_tree)


def lower_cell(arch: str, shape_name: str, mesh, multi_pod: bool,
               serve_variant: str = "baseline", train_fsdp: bool = True,
               exit_idx: Optional[int] = None,
               overrides: Optional[dict] = None):
    """Lower + compile one (arch x shape) cell. Returns the result record.

    ``overrides`` hot-patches LMConfig fields for §Perf variants (e.g.
    {"rwkv_chunk": 32}, {"mla_absorbed_decode": True},
    {"vocab_pad_multiple": 256}).
    """
    import dataclasses as _dc
    cfg = get_config(arch)
    if overrides:
        cfg = _dc.replace(cfg, **overrides)
    model = build_model(cfg)
    key = jax.random.key(0)
    shapes, axes = model.abstract(key)
    kind, kw = input_specs(cfg, shape_name, exit_idx=exit_idx)
    spec = SHAPES[shape_name]
    if kind != "train":
        shapes = _serve_cast(shapes, cfg.dtype)

    t0 = time.time()
    if kind == "train":
        if serve_variant == "pure-dp":
            from repro.distributed.sharding import train_rules_pure_dp
            rules = train_rules_pure_dp(multi_pod=multi_pod)
        else:
            rules = train_rules(multi_pod=multi_pod, fsdp=train_fsdp)
        p_sh = param_shardings(shapes, axes, rules, mesh)
        opt = pick_optimizer_for(cfg)
        opt_shapes = abstract_opt_state(opt, shapes)
        opt_sh = opt_state_shardings(opt, shapes, axes, rules, mesh)
        b_sh = batch_shardings(kw["batch"], rules, mesh)
        scalar_sh = NamedSharding(mesh, P())
        step_fn = make_train_step(model, opt)
        fn = jax.jit(
            step_fn,
            in_shardings=(p_sh, opt_sh, b_sh, scalar_sh),
            out_shardings=(p_sh, opt_sh, None),
            donate_argnums=(0, 1),
        )
        lowered = fn.lower(shapes, opt_shapes, kw["batch"],
                           jax.ShapeDtypeStruct((), jnp.int32))
        arg_trees = [(shapes, p_sh), (opt_shapes, opt_sh),
                     (kw["batch"], b_sh)]
    elif kind == "prefill":
        from repro.distributed.sharding import serve_rules_ep_wide
        rules = (serve_rules_ep_wide(multi_pod) if serve_variant == "ep-wide"
                 else serve_rules(multi_pod=multi_pod))
        p_sh = param_shardings(shapes, axes, rules, mesh)
        b_sh = batch_shardings(kw["batch"], rules, mesh)
        e = kw["exit_idx"]
        fn = jax.jit(
            lambda v, b: model.prefill(v, b, e),
            in_shardings=(p_sh, b_sh),
        )
        lowered = fn.lower(shapes, kw["batch"])
        arg_trees = [(shapes, p_sh), (kw["batch"], b_sh)]
    else:  # decode
        from repro.distributed.sharding import serve_rules_ep_wide
        rules = (serve_rules_ep_wide(multi_pod) if serve_variant == "ep-wide"
                 else serve_rules(multi_pod=multi_pod))
        p_sh = param_shardings(shapes, axes, rules, mesh)
        tok_sh = batch_shardings(kw["token"], rules, mesh)
        c_sh = cache_shardings(kw["cache"], rules, mesh)
        e = kw["exit_idx"]
        fn = jax.jit(
            lambda v, t, c: model.decode_step(v, t, c, e),
            in_shardings=(p_sh, tok_sh, c_sh),
            donate_argnums=(2,),
        )
        lowered = fn.lower(shapes, kw["token"], kw["cache"])
        arg_trees = [(shapes, p_sh), (kw["token"], tok_sh),
                     (kw["cache"], c_sh)]
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    # --- extract analysis ---------------------------------------------------
    try:
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0]
        cost = {k: float(v) for k, v in cost.items()
                if isinstance(v, (int, float)) and k in
                ("flops", "bytes accessed", "transcendentals",
                 "utilization operand 0", "bytes accessed output")}
    except Exception as ex:  # pragma: no cover
        cost = {"error": str(ex)}
    try:
        mem = compiled.memory_analysis()
        mem_d = {
            a: float(getattr(mem, a))
            for a in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "alias_size_in_bytes",
                      "generated_code_size_in_bytes")
            if hasattr(mem, a)
        }
    except Exception as ex:  # pragma: no cover
        mem_d = {"error": str(ex)}

    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    # Trip-count-aware FLOP/byte accounting: XLA's cost_analysis counts a
    # while (scan-over-layers) body once; hlo_metrics re-derives both with
    # known_trip_count weighting (see hlo_analysis.py).
    tripaware = hlo_metrics(hlo)

    static_bytes = sum(
        _tree_bytes_per_device(tree, sh, mesh) for tree, sh in arg_trees
    )

    return {
        "arch": arch,
        "shape": shape_name,
        "kind": kind,
        "mesh": list(mesh.devices.shape),
        "mesh_axes": list(mesh.axis_names),
        "num_devices": int(mesh.devices.size),
        "rules": rules.name,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "cost_analysis": cost,
        "hlo_metrics": tripaware,
        "memory_analysis": mem_d,
        "collectives": coll,
        "bytes_per_device_static": static_bytes,
        "model_flops": _model_flops(cfg, shapes, kind, spec),
        "hlo_bytes": len(hlo),
        "serve_variant": serve_variant,
        "overrides": overrides or {},
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="one arch id (default: all)")
    ap.add_argument("--shape", default=None, help="one shape (default: all)")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--serve-variant", default="baseline",
                    choices=["baseline", "ep-wide", "pure-dp"])
    ap.add_argument("--no-fsdp", action="store_true",
                    help="train with pure DP instead of FSDP (perf ablation)")
    ap.add_argument("--exit", type=int, default=None,
                    help="exit index for serve shapes (default: final)")
    ap.add_argument("--rwkv-chunk", type=int, default=0,
                    help="§Perf: chunked-parallel WKV chunk length")
    ap.add_argument("--mla-absorbed", action="store_true",
                    help="§Perf: absorbed-matrix MLA decode")
    ap.add_argument("--pad-vocab", type=int, default=0,
                    help="§Perf: pad vocab to a multiple for sharding")
    ap.add_argument("--tag", default="",
                    help="suffix for the output json (variant label)")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()

    overrides = {}
    if args.rwkv_chunk:
        overrides["rwkv_chunk"] = args.rwkv_chunk
    if args.mla_absorbed:
        overrides["mla_absorbed_decode"] = True
    if args.pad_vocab:
        overrides["vocab_pad_multiple"] = args.pad_vocab

    archs = [args.arch] if args.arch else ARCH_IDS
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    cells = []
    for a in archs:
        cfg = get_config(a)
        for s in shapes:
            if applicable(cfg, s):
                cells.append((a, s))
            else:
                cells.append((a, s, skip_reason(cfg, s)))
    if args.list:
        for c in cells:
            print(c)
        return

    os.makedirs(args.out, exist_ok=True)
    n_fail = 0
    for multi_pod in meshes:
        mesh = make_production_mesh(multi_pod=multi_pod)
        mesh_name = "multi" if multi_pod else "single"
        for cell in cells:
            a, s = cell[0], cell[1]
            tag = f"{mesh_name}/{a}__{s}"
            out_path = os.path.join(
                args.out, mesh_name,
                f"{a}__{s}"
                + ("" if args.serve_variant == "baseline"
                   else f"__{args.serve_variant}")
                + ("" if args.exit is None else f"__e{args.exit}")
                + (f"__{args.tag}" if args.tag else "")
                + ".json")
            os.makedirs(os.path.dirname(out_path), exist_ok=True)
            if len(cell) == 3:
                rec = {"arch": a, "shape": s, "skipped": cell[2],
                       "mesh": mesh_name}
                with open(out_path, "w") as f:
                    json.dump(rec, f, indent=1)
                print(f"[skip] {tag}: {cell[2]}")
                continue
            try:
                rec = lower_cell(a, s, mesh, multi_pod,
                                 serve_variant=args.serve_variant,
                                 train_fsdp=not args.no_fsdp,
                                 exit_idx=args.exit,
                                 overrides=overrides)
                with open(out_path, "w") as f:
                    json.dump(rec, f, indent=1)
                ca = rec["cost_analysis"]
                print(
                    f"[ok]   {tag}: compile={rec['compile_s']:.1f}s "
                    f"flops={ca.get('flops', float('nan')):.3e} "
                    f"coll={rec['collectives']['bytes']['total']:.3e}B "
                    f"static={rec['bytes_per_device_static']/2**30:.2f}GiB/dev"
                )
            except Exception:
                n_fail += 1
                err = traceback.format_exc()
                with open(out_path, "w") as f:
                    json.dump({"arch": a, "shape": s, "mesh": mesh_name,
                               "error": err[-4000:]}, f, indent=1)
                print(f"[FAIL] {tag}:\n{err[-2000:]}")
    print(f"done; failures: {n_fail}")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
