"""Training driver.

Local (this container):
  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m --smoke \
      --steps 100 --batch 8 --seq 64

Production posture (TPU pod): the same entry point — the mesh comes from
``make_production_mesh()``, params/optimizer are sharded by the train
rules, checkpoints are written asynchronously, and preemption triggers a
final checkpoint + clean exit (see repro.runtime.fault_tolerance).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data.pipeline import synthetic_lm_batches
from repro.models import build_model, split_params
from repro.optim import cosine_schedule
from repro.runtime.checkpoint import Checkpointer
from repro.runtime.fault_tolerance import PreemptionGuard
from repro.runtime.trainer import make_train_step, pick_optimizer_for


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    model = build_model(cfg)
    values, _ = split_params(model.init(jax.random.key(args.seed)))
    n_params = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(values))
    print(f"arch={cfg.arch_id} params={n_params/1e6:.1f}M "
          f"exits={cfg.exits} devices={len(jax.devices())}")

    opt = pick_optimizer_for(cfg, lr=cosine_schedule(args.lr, 20, args.steps))
    opt_state = opt.init(values)
    step_fn = jax.jit(make_train_step(model, opt, grad_accum=args.grad_accum))

    ck = None
    start_step = 0
    if args.checkpoint_dir:
        ck = Checkpointer(args.checkpoint_dir)
        if args.resume and ck.latest_step() is not None:
            start_step, state, _ = ck.restore(
                template={"values": values, "opt": opt_state})
            values, opt_state = state["values"], state["opt"]
            print(f"resumed from step {start_step}")

    guard = PreemptionGuard(install_sigterm=True)
    batches = synthetic_lm_batches(
        vocab=cfg.vocab_size, batch=args.batch, seq=args.seq,
        seed=args.seed, encdec=cfg.family == "encdec",
        d_model=cfg.d_model, src_len=max(cfg.frontend_seq, 8),
        vision=cfg.frontend == "vision")

    t0 = time.time()
    for step in range(start_step, args.steps):
        batch = next(batches)
        values, opt_state, metrics = step_fn(values, opt_state, batch, step)
        if step % args.log_every == 0 or step == args.steps - 1:
            loss = float(metrics["loss"])
            per_exit = [
                float(metrics[k]) for k in sorted(metrics)
                if k.startswith("nll_exit")
            ]
            dt = (time.time() - t0) / max(step - start_step + 1, 1)
            print(f"step {step:5d} loss={loss:.4f} "
                  f"exits={['%.3f' % e for e in per_exit]} "
                  f"gnorm={float(metrics['grad_norm']):.3f} {dt:.2f}s/step")
        if ck and (step % args.checkpoint_every == 0 or
                   step == args.steps - 1 or guard.should_stop()):
            ck.save(step + 1, {"values": values, "opt": opt_state},
                    extra={"loss": float(metrics["loss"])})
        if guard.should_stop():
            print("preemption requested: checkpointed and exiting cleanly")
            break
    if ck:
        ck.wait()
    print("done")


if __name__ == "__main__":
    main()
