"""Roofline analysis over the dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch x shape x mesh) cell:

    compute term    = HLO_FLOPs_per_device / 197 TFLOP/s        (bf16 MXU)
    memory term     = HLO_bytes_per_device / 819 GB/s           (HBM)
    collective term = collective_bytes_per_device / 50 GB/s     (ICI link)

All three inputs are per-device quantities of the SPMD-partitioned program
(verified against a known matmul in tests), with while-loop trip-count
weighting re-derived from the HLO text (XLA's cost_analysis counts scan
bodies once — see hlo_analysis.py). The step-time bound is
T* = max(terms); the roofline fraction reported in §Perf is

    frac = (MODEL_FLOPS / devices / PEAK) / T*

i.e. the best-achievable useful-FLOP utilisation of the compiled program —
waste (remat, replicated compute from unshardable reshapes, dispatch
overhead) shows up as MODEL_FLOPS/HLO_FLOPs < 1.

Usage:
  PYTHONPATH=src python -m repro.launch.roofline --artifacts artifacts/dryrun
"""

from __future__ import annotations

import argparse
import json
import os
from typing import Dict, List, Optional

from repro.launch.mesh import HBM_BW, ICI_BW_PER_LINK, PEAK_FLOPS_BF16


def analyze_record(rec: dict) -> Optional[dict]:
    if "error" in rec or "skipped" in rec:
        return None
    n_dev = rec["num_devices"]
    flops = rec["hlo_metrics"]["flops"]
    nbytes = rec["hlo_metrics"]["bytes"]
    coll = rec["collectives"]["bytes"]["total"]

    compute_s = flops / PEAK_FLOPS_BF16
    memory_s = nbytes / HBM_BW
    collective_s = coll / ICI_BW_PER_LINK
    t_star = max(compute_s, memory_s, collective_s, 1e-12)
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)

    model_flops_dev = rec["model_flops"] / n_dev
    useful_ratio = rec["model_flops"] / max(flops * n_dev, 1e-9)
    frac = (model_flops_dev / PEAK_FLOPS_BF16) / t_star

    return {
        "arch": rec["arch"],
        "shape": rec["shape"],
        "kind": rec["kind"],
        "mesh": "x".join(str(x) for x in rec["mesh"]),
        "variant": rec.get("serve_variant", "baseline"),
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "t_star": t_star,
        "dominant": dominant,
        "model_flops": rec["model_flops"],
        "useful_ratio": useful_ratio,
        "roofline_frac": frac,
        "static_gib": rec["bytes_per_device_static"] / 2**30,
        "advice": advice(dominant, useful_ratio, rec),
    }


def advice(dominant: str, useful_ratio: float, rec: dict) -> str:
    """One sentence on what would move the dominant term down."""
    arch, kind = rec["arch"], rec["kind"]
    if useful_ratio < 0.25 and dominant == "compute":
        return ("compute-bound but <25% useful FLOPs — replicated/redundant "
                "compute from unshardable head/reshape dims or remat; fix "
                "the sharding of the offending einsum")
    if dominant == "compute":
        return ("compute-bound near the useful-FLOP ceiling — gains come "
                "from kernel fusion (flash attention) and skipping masked "
                "work, not layout")
    if dominant == "memory":
        if kind == "decode":
            return ("HBM-bound on KV/state streaming — shrink the cache "
                    "(MLA latent/quantised KV) or batch more decode streams "
                    "per weight pass")
        return ("HBM-bound — increase arithmetic intensity: larger per-chip "
                "tiles, bf16 everywhere, fuse elementwise chains into the "
                "matmuls")
    return ("collective-bound — re-shard to cut the largest all-gather "
            "(FSDP prefetch overlap, or move TP to the axis with the "
            "smaller activation), and overlap collectives with compute")


def load_cells(artifacts: str, mesh_dir: str) -> List[dict]:
    out = []
    d = os.path.join(artifacts, mesh_dir)
    if not os.path.isdir(d):
        return out
    for name in sorted(os.listdir(d)):
        if not name.endswith(".json"):
            continue
        with open(os.path.join(d, name)) as f:
            rec = json.load(f)
        row = analyze_record(rec)
        if row is not None:
            row["_file"] = name
            out.append(row)
    return out


def fmt_s(x: float) -> str:
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.2f}ms"
    return f"{x*1e6:.1f}us"


def markdown_table(rows: List[dict]) -> str:
    hdr = ("| arch | shape | kind | compute | memory | collective | bound | "
           "useful | roofline frac |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    lines = []
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['kind']} | "
            f"{fmt_s(r['compute_s'])} | {fmt_s(r['memory_s'])} | "
            f"{fmt_s(r['collective_s'])} | **{r['dominant']}** | "
            f"{r['useful_ratio']:.2f} | {r['roofline_frac']:.2%} |"
        )
    return hdr + "\n".join(lines) + "\n"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--artifacts", default="artifacts/dryrun")
    ap.add_argument("--out", default="artifacts/roofline")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    for mesh_dir in ("single", "multi"):
        rows = load_cells(args.artifacts, mesh_dir)
        if not rows:
            continue
        md = markdown_table(rows)
        with open(os.path.join(args.out, f"roofline_{mesh_dir}.md"), "w") as f:
            f.write(md)
        with open(os.path.join(args.out, f"roofline_{mesh_dir}.json"), "w") as f:
            json.dump(rows, f, indent=1)
        print(f"== {mesh_dir} ==")
        print(md)
        for r in rows:
            print(f"  {r['arch']}/{r['shape']}: {r['advice']}")


if __name__ == "__main__":
    main()
