"""Logical-axis -> mesh-axis sharding rules (DP / FSDP / TP / EP / SP + pod).

Every parameter carries logical axis names (see repro.models.common.Param);
these rules map them to mesh axes and build NamedSharding trees for jit's
in_shardings/out_shardings. Divisibility is sanitised: a mesh axis that does
not evenly divide the corresponding array dim is dropped from the spec
(replicating that dim) instead of failing — e.g. seamless' 256,206-row
vocab is not 16-divisible, starcoder2's 36 heads reshape unevenly.

Rule presets:
  * train_rules: Megatron-style TP over "model" (heads/mlp/expert/vocab) +
    FSDP over ("pod","data") for the remaining large dims ("embed") —
    ZeRO-3-equivalent: optimizer states inherit param specs.
  * serve_rules: TP only; params replicated across "data"/"pod" (each data
    shard serves its own requests); KV caches sharded batch->data,
    sequence->model (flash-decode style sequence parallelism).
  * serve_rules_ep_wide: beyond-paper §Perf variant — experts sharded over
    ("data","model") (e.g. 256-way EP for deepseek-v3), tokens replicated
    across "data" during expert compute.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AxisVal = Union[None, str, Tuple[str, ...]]


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Mapping from logical axis names to mesh axes."""

    rules: "dict[str, AxisVal]"
    # Activation conventions (used by batch/cache spec builders).
    batch_axes: AxisVal = ("data",)
    seq_axes: AxisVal = None       # sequence-parallel axis for caches
    name: str = "custom"

    def axis_for(self, logical: Optional[str]) -> AxisVal:
        if logical is None:
            return None
        return self.rules.get(logical)


def train_rules(multi_pod: bool = False, fsdp: bool = True) -> ShardingRules:
    dp: Tuple[str, ...] = ("pod", "data") if multi_pod else ("data",)
    return ShardingRules(
        rules={
            "vocab": "model",
            "embed": dp if fsdp else None,
            "heads": "model",
            "mlp": "model",
            "expert": "model",
            "embed_out": None,
            "layers": None,
        },
        batch_axes=dp,
        seq_axes=None,
        name=("train-fsdp" if fsdp else "train-dp")
        + ("-multipod" if multi_pod else ""),
    )


def train_rules_pure_dp(multi_pod: bool = False) -> ShardingRules:
    """§Perf variant for small models whose head counts defeat 16-way TP
    (e.g. smollm's 9 heads): classic data parallelism — params fully
    replicated (135M fp32 = 0.5 GB, fits every chip), batch sharded over
    BOTH mesh axes (256/512-way DP). Every chip computes distinct
    sequences; no replicated attention, and — critically — the embedding
    gather stays trivially batch-sharded (an FSDP-sharded table makes the
    gather unpartitionable: XLA's "involuntary full rematerialization"
    replicates the activations and the whole forward loses its batch
    sharding — measured in §Perf Cell D)."""
    dp: Tuple[str, ...] = (("pod", "data", "model") if multi_pod
                           else ("data", "model"))
    return ShardingRules(
        rules={
            "vocab": None,
            "embed": None,
            "heads": None,
            "mlp": None,
            "expert": None,
            "embed_out": None,
            "layers": None,
        },
        batch_axes=dp,
        seq_axes=None,
        name="train-pure-dp" + ("-multipod" if multi_pod else ""),
    )


def serve_rules(multi_pod: bool = False) -> ShardingRules:
    dp: Tuple[str, ...] = ("pod", "data") if multi_pod else ("data",)
    return ShardingRules(
        rules={
            "vocab": "model",
            "embed": None,          # replicated: every data shard serves alone
            "heads": "model",
            "mlp": "model",
            "expert": "model",
            "embed_out": None,
            "layers": None,
        },
        batch_axes=dp,
        seq_axes="model",           # KV cache sequence-sharding (flash-decode)
        name="serve" + ("-multipod" if multi_pod else ""),
    )


def serve_rules_ep_wide(multi_pod: bool = False) -> ShardingRules:
    """Beyond-paper serving layout for huge MoE: experts sharded over the
    full chip count (EP = data x model) and non-expert params FSDP-sharded
    over "data" — the layout that brings deepseek-v3 weights under v5e HBM
    (see EXPERIMENTS.md §Perf)."""
    base = serve_rules(multi_pod)
    return dataclasses.replace(
        base,
        rules={**base.rules, "expert": ("data", "model"), "embed": "data"},
        name="serve-ep-wide" + ("-multipod" if multi_pod else ""),
    )


# ---------------------------------------------------------------------------
# Spec construction + sanitisation
# ---------------------------------------------------------------------------

def _axis_size(mesh: Mesh, ax: AxisVal) -> int:
    if ax is None:
        return 1
    if isinstance(ax, str):
        return mesh.shape[ax]
    return int(np.prod([mesh.shape[a] for a in ax]))


def sanitize_spec(shape: Sequence[int], spec: P, mesh: Mesh) -> P:
    """Drop mesh axes that don't divide the dim (replicate instead),
    and drop axes that appear more than once across dims."""
    used: set = set()
    out = []
    for dim, ax in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if ax is None:
            out.append(None)
            continue
        axes = (ax,) if isinstance(ax, str) else tuple(ax)
        keep = []
        size = 1
        for a in axes:
            if a in used:
                continue
            s = mesh.shape[a]
            if dim % (size * s) == 0:
                keep.append(a)
                size *= s
        used.update(keep)
        if not keep:
            out.append(None)
        elif len(keep) == 1:
            out.append(keep[0])
        else:
            out.append(tuple(keep))
    return P(*out)


def spec_for_param(shape: Sequence[int], axes: Tuple[Optional[str], ...],
                   rules: ShardingRules, mesh: Mesh) -> P:
    spec = P(*[rules.axis_for(a) for a in axes])
    return sanitize_spec(shape, spec, mesh)


def param_shardings(shapes_tree, axes_tree, rules: ShardingRules, mesh: Mesh):
    """NamedSharding tree matching a ShapeDtypeStruct (or array) tree."""

    def one(s, a):
        return NamedSharding(mesh, spec_for_param(s.shape, a, rules, mesh))

    return jax.tree.map(one, shapes_tree, axes_tree)


# -- activation / input specs -------------------------------------------------

def batch_shardings(batch_tree, rules: ShardingRules, mesh: Mesh):
    """Shard every batch input along its leading (batch) dim."""

    def one(s):
        spec = P(rules.batch_axes, *([None] * (len(s.shape) - 1)))
        return NamedSharding(mesh, sanitize_spec(s.shape, spec, mesh))

    return jax.tree.map(one, batch_tree)


def _cache_leaf_spec(path_str: str, shape, rules: ShardingRules) -> P:
    """Spec for one KV-cache / state leaf by naming convention.

    Stacked cache layouts (leading ``layers`` axis):
      k/v:    [L, B, S, K, Dh]   -> (None, batch, seq, None, None)
      c_kv:   [L, B, S, dc]      -> (None, batch, seq, None)   (MLA latent)
      k_pe:   [L, B, S, r]       -> (None, batch, seq, None)
      len:    [L, B]             -> (None, batch)
      wkv:    [L, B, H, N, N]    -> (None, batch, model, None, None)
      shift:  [L, B, D]          -> (None, batch, None)
      h:      [L, B, Di, N]      -> (None, batch, model, None)  (mamba)
      conv:   [L, B, K-1, Di]    -> (None, batch, None, None)
    """
    nd = len(shape)
    b = rules.batch_axes
    s = rules.seq_axes
    leaf = path_str.rsplit("/", 1)[-1]
    if leaf in ("k", "v") and nd == 5:
        return P(None, b, s, None, None)
    if leaf in ("c_kv", "k_pe") and nd == 4:
        return P(None, b, s, None)
    if leaf == "len":
        return P(*([None] * (nd - 1) + [b])) if nd == 1 else P(None, b)
    if leaf == "wkv" and nd == 5:
        return P(None, b, "model", None, None)
    if leaf == "h" and nd == 4:
        return P(None, b, "model", None)
    if leaf in ("shift", "conv"):
        return P(None, b, *([None] * (nd - 2)))
    # fallback: batch on dim 1 (after layers)
    return P(None, b, *([None] * (nd - 2))) if nd >= 2 else P(None)


def cache_shardings(cache_tree, rules: ShardingRules, mesh: Mesh):
    def one(path, s):
        path_str = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        spec = _cache_leaf_spec(path_str, s.shape, rules)
        return NamedSharding(mesh, sanitize_spec(s.shape, spec, mesh))

    return jax.tree_util.tree_map_with_path(one, cache_tree)


def replicated(tree, mesh: Mesh):
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)
