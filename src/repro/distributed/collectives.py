"""Distributed-optimization collectives.

``compressed_psum``: int8-quantised gradient all-reduce with error feedback
— an O(4x) reduction of the gradient all-reduce volume for DP/FSDP training
at 1000+ node scale, where the cross-pod (DCI) links are the binding
constraint. Used by the trainer when ``compress_grads=True``: gradients are
quantised per-tensor with a shared scale, summed in int32, dequantised, and
the quantisation error is fed back into the next step's gradients (error
feedback keeps SGD convergence unbiased to first order).

These helpers are written against ``shard_map`` semantics (explicit mesh
axes); under plain pjit the trainer uses them through
``quantize_tree``/``dequantize_tree`` around the optimizer boundary.
"""

from __future__ import annotations

import functools
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 quantisation. Returns (q, scale)."""
    absmax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    scale = jnp.maximum(absmax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(x: jax.Array, axis_name: str) -> jax.Array:
    """int8-compressed psum over a mesh axis (inside shard_map).

    The int8 payload is psum-ed in int32 (no overflow for <= 2^23 workers);
    scales are max-reduced so dequantisation is conservative.
    """
    q, scale = quantize_int8(x)
    q_sum = jax.lax.psum(q.astype(jnp.int32), axis_name)
    scale_max = jax.lax.pmax(scale, axis_name)
    return dequantize_int8(q_sum, scale_max).astype(x.dtype)


def quantize_tree(grads: PyTree, error: Optional[PyTree]) -> Tuple[PyTree, PyTree, PyTree]:
    """Error-feedback quantisation of a gradient tree.

    Returns (quantised-dequantised grads, scales, new error residuals).
    The trainer adds ``error`` (previous residual) before quantising, then
    keeps the new residual for the next step.
    """
    if error is None:
        error = jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), grads)

    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        q, scale = quantize_int8(g32)
        deq = dequantize_int8(q, scale)
        return deq.astype(g.dtype), scale, g32 - deq

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(error)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    deq = treedef.unflatten([o[0] for o in outs])
    scales = treedef.unflatten([o[1] for o in outs])
    residual = treedef.unflatten([o[2] for o in outs])
    return deq, scales, residual
