"""End-to-end training driver: joint early-exit LM training with
checkpoint/restart. Defaults to a CPU-sized model; ``--full`` trains the
real smollm-135m config (the ~100M-class model) — same code path.

  PYTHONPATH=src python examples/train_early_exit_lm.py --steps 200
  PYTHONPATH=src python examples/train_early_exit_lm.py --full --steps 300
"""

import argparse
import subprocess
import sys


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--full", action="store_true",
                    help="train the full smollm-135m config (slow on CPU)")
    ap.add_argument("--checkpoint-dir", default="/tmp/repro_ckpt")
    args = ap.parse_args()

    cmd = [
        sys.executable, "-m", "repro.launch.train",
        "--arch", "smollm-135m",
        "--steps", str(args.steps),
        "--batch", "8", "--seq", "64",
        "--checkpoint-dir", args.checkpoint_dir,
        "--checkpoint-every", "50",
    ]
    if not args.full:
        cmd.append("--smoke")
    print("+", " ".join(cmd))
    raise SystemExit(subprocess.call(cmd))


if __name__ == "__main__":
    main()
