"""Fault-tolerance walkthrough: checkpoint -> simulated preemption ->
elastic restore. Trains a tiny early-exit LM, checkpoints asynchronously,
"kills" the run mid-flight, then restores from the last committed step and
verifies training continues bit-exactly from the checkpoint.

  PYTHONPATH=src python examples/elastic_failover.py
"""

import tempfile

import jax
import numpy as np

from repro.configs import get_config
from repro.data import synthetic_memorization_corpus
from repro.models import build_model, split_params
from repro.optim import AdamW
from repro.runtime.checkpoint import Checkpointer
from repro.runtime.fault_tolerance import ElasticMesh, PreemptionGuard
from repro.runtime.trainer import make_train_step


def main():
    cfg = get_config("smollm-135m", smoke=True)
    model = build_model(cfg)
    values, _ = split_params(model.init(jax.random.key(0)))
    opt = AdamW(lr=3e-3, weight_decay=0.0)
    opt_state = opt.init(values)
    step_fn = jax.jit(make_train_step(model, opt))
    batch = synthetic_memorization_corpus(cfg.vocab_size)

    with tempfile.TemporaryDirectory() as root:
        ck = Checkpointer(root, keep=2)
        guard = PreemptionGuard()

        print("== phase 1: train 30 steps, checkpoint every 10 ==")
        losses = []
        for step in range(30):
            values, opt_state, metrics = step_fn(values, opt_state, batch,
                                                 step)
            losses.append(float(metrics["loss"]))
            if (step + 1) % 10 == 0:
                ck.save(step + 1, {"values": values, "opt": opt_state})
            if step == 24:
                guard.request_stop()  # preemption notice arrives
            if guard.should_stop():
                ck.save(step + 1, {"values": values, "opt": opt_state})
                print(f"preempted at step {step + 1}: drained + checkpointed "
                      f"(loss {losses[-1]:.4f})")
                break
        ck.wait()

        print(f"committed checkpoints: {ck.committed_steps()}")

        print("== phase 2: elastic restart ==")
        em = ElasticMesh(model_axis=1)
        mesh, accum = em.build()
        print(f"rebuilt mesh over {mesh.devices.size} device(s), "
              f"grad-accum multiplier {accum}")
        step0, state, _ = ck.restore(
            template={"values": values, "opt": opt_state})
        values2, opt2 = state["values"], state["opt"]
        print(f"restored step {step0}")

        # continue; the restored run must match an uninterrupted one
        v_a, o_a = values, opt_state
        v_b, o_b = values2, opt2
        for step in range(step0, step0 + 5):
            v_a, o_a, m_a = step_fn(v_a, o_a, batch, step)
            v_b, o_b, m_b = step_fn(v_b, o_b, batch, step)
        diff = max(
            float(np.max(np.abs(np.asarray(x) - np.asarray(y))))
            for x, y in zip(jax.tree.leaves(v_a), jax.tree.leaves(v_b))
        )
        print(f"post-restore divergence vs uninterrupted run: {diff:.2e}")
        assert diff < 1e-6
        print("restart is bit-faithful: OK")


if __name__ == "__main__":
    main()
