"""Quickstart: the EdgeServing scheduler in 40 lines.

Builds the paper-calibrated profile table, runs one serving experiment for
EdgeServing and All-Final at high traffic, and prints the comparison the
whole paper is about.

  PYTHONPATH=src python examples/quickstart.py
"""

from repro.core import (
    ProfileTable,
    SchedulerConfig,
    make_scheduler,
    paper_rate_vector,
    run_experiment,
)


def main():
    # Offline phase: the profile table L(m, e, B) (paper Sec. IV).
    table = ProfileTable.paper_rtx3080()
    print(f"profile: {table.model_names} x {table.exit_names} x "
          f"B<={table.max_batch}")

    # Online phase: 20 s of Poisson traffic at lambda_152 = 200 req/s
    # (3:2:1 rate ratio), tau = 50 ms.
    cfg = SchedulerConfig(slo=0.050, max_batch=10)
    for name in ("edgeserving", "all-final", "all-early", "symphony"):
        sched = make_scheduler(name, table, cfg)
        res = run_experiment(sched, table, paper_rate_vector(200),
                             horizon=20.0, seed=0)
        m = res.metrics
        print(f"{name:12s}: P95={m.p95_latency*1e3:8.2f} ms  "
              f"violations={m.violation_ratio*100:6.2f}%  "
              f"accuracy={m.mean_accuracy*100:5.2f}%  "
              f"mean_exit_depth={m.mean_exit_depth:.2f}")

    print("\nEdgeServing holds P95 under the 50 ms SLO with <1% violations "
          "by trading exit depth for queue drain rate; All-Final collapses "
          "past the saturation point (paper Fig. 4).")


if __name__ == "__main__":
    main()
