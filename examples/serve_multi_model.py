"""End-to-end live serving driver (the paper's kind of deployment, real
execution): three early-exit LMs of increasing cost share one accelerator
under time-division; the offline phase measures the real profile table;
the online phase serves a Poisson trace with the EdgeServing scheduler and
reports SLO compliance. Everything here runs the actual jitted models.

  PYTHONPATH=src python examples/serve_multi_model.py [--duration 3.0]
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import EdgeServingScheduler, SchedulerConfig, poisson_arrivals
from repro.models import build_model, split_params
from repro.models.transformer import LMConfig
from repro.runtime.server import ServedModel, ServingEngine, measure_profile


def make_deployment():
    """Three early-exit LMs: cost ordering mimics R50 < R101 < R152."""
    models = []
    for i, (layers, d) in enumerate([(2, 64), (2, 128), (4, 128)]):
        cfg = LMConfig(
            arch_id=f"lm{i}", family="dense", num_layers=layers,
            d_model=d, num_heads=4, num_kv_heads=2, d_ff=4 * d,
            vocab_size=512, exits=tuple(range(1, layers + 1)),
        )
        model = build_model(cfg)
        values, _ = split_params(model.init(jax.random.key(i)))

        def forward(v, x, e, _m=model):
            return _m.forward_exit(v, {"tokens": x}, e)

        def data(b, _v=cfg.vocab_size):
            return jnp.zeros((b, 16), jnp.int32)

        models.append(ServedModel(
            name=f"lm{i}-{layers}L-d{d}", values=values, forward_fn=forward,
            data_fn=data, num_exits=cfg.num_exits))
    # pad exit counts: profile table needs uniform E -> use min
    e_min = min(m.num_exits for m in models)
    for m in models:
        m.num_exits = e_min
    return models


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--duration", type=float, default=3.0)
    ap.add_argument("--rate", type=float, default=150.0,
                    help="total request rate (req/s), 3:2:1 split")
    args = ap.parse_args()

    models = make_deployment()
    print("== offline profiling phase (real wall-clock, this machine) ==")
    table = measure_profile(models, batch_sizes=[1, 2, 4, 8], repeats=5,
                            warmup=2)
    for mi, name in enumerate(table.model_names):
        lat = ", ".join(
            f"{e}={table.latency[mi, ei, 0]*1e3:.2f}ms"
            for ei, e in enumerate(table.exit_names))
        print(f"  {name}: B=1 {lat}")

    # SLO: 5x the slowest profiled quantum (CPU latencies are ~ms-scale)
    slo = float(table.latency.max() * 5)
    print(f"SLO tau = {slo*1e3:.1f} ms")

    cfg = SchedulerConfig(slo=slo, max_batch=8)
    engine = ServingEngine(models, EdgeServingScheduler(table, cfg))
    print("== warmup: compiling every (m, e, B) ==")
    engine.warmup([1, 2, 4, 8])

    unit = args.rate / 6.0
    arrivals = poisson_arrivals([3 * unit, 2 * unit, unit], args.duration,
                                seed=42)
    print(f"== online serving phase: {len(arrivals)} requests over "
          f"{args.duration:.1f}s ==")
    completions, span = engine.run(arrivals, args.duration, drain=True)
    m = engine.metrics(table, slo=slo, span=span)
    print(f"completed={m.num_completed} dropped={m.dropped} "
          f"P95={m.p95_latency*1e3:.2f}ms violations={m.violation_ratio*100:.2f}% "
          f"mean_exit_depth={m.mean_exit_depth:.2f} util={m.utilization:.2f}")
    exits = np.array([c.exit_idx for c in completions])
    for e in range(int(exits.max()) + 1):
        print(f"  exit {e}: {np.mean(exits == e)*100:.1f}% of requests")


if __name__ == "__main__":
    main()
